"""F2P sketch engine demo (DESIGN.md §6): ingest a synthetic Zipf packet
trace through the streaming engine and recover the heavy hitters.

1. Generate ~1M packet arrivals over a 1M-flow space, Zipf-1.2 skewed
   (a few elephant flows, a long mouse tail) — the paper's network-
   measurement setting (Sec. III-A).
2. Stream them in odd-sized chunks through `SketchIngestEngine`: re-batched
   into fixed device batches, counted by a 4x4096 count-min sketch of 12-bit
   F2P_LI^2 grid-counter cells (32 KiB of registers for 1M flows; the
   12-bit LI^2 range ~2M covers the elephants — 8-bit would saturate at
   ~130k).
3. Print the top-10 report vs ground truth, plus accuracy/throughput stats.
   The trace is streamed twice: the first pass pays jit compilation and the
   dense grid head (many advance sweeps/cell), the second shows steady
   state.

    PYTHONPATH=src python examples/sketch_zipf_trace.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.serve.engine import SketchIngestEngine
from repro.sketch import F2PSketch, SketchConfig


def make_trace(n_packets: int, n_flows: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    ranks = rng.zipf(1.2, size=n_packets)
    # scramble rank -> flow id so heavy flows aren't the small integers
    return (ranks.astype(np.int64) * 0x9E3779B1) % n_flows


def main() -> None:
    n_packets, n_flows = 1 << 20, 1 << 20
    trace = make_trace(n_packets, n_flows)

    sk = F2PSketch(SketchConfig(depth=4, width=4096, n_bits=12, h_bits=2,
                                flavor="li", backend="xla"))
    eng = SketchIngestEngine(sk, batch=1 << 16, track_top=128)

    rng = np.random.default_rng(1)
    rates = []
    for phase in ("cold (compile + dense grid head)", "steady state"):
        t0 = time.perf_counter()
        pos = 0
        while pos < len(trace):  # odd-sized chunks, as a packet feed would
            n = int(rng.integers(10_000, 90_000))
            eng.ingest(trace[pos:pos + n])
            pos += n
        eng.flush()
        dt = time.perf_counter() - t0
        rates.append(len(trace) / dt / 1e6)
        print(f"{phase}: {len(trace):,} packets in {dt:.2f}s "
              f"({rates[-1]:.1f}M arrivals/s)")
    print(f"sketch: {sk.cfg.depth}x{sk.cfg.width} 12-bit F2P_LI^2 cells = "
          f"{sk.nbytes / 1024:.0f} KiB of registers, fill {sk.fill():.0%}, "
          f"backend={sk.backend}\n")

    # ground truth for the doubled trace (two identical passes)
    uniq, cnt = np.unique(trace, return_counts=True)
    cnt = cnt * 2
    order = np.argsort(cnt)[::-1]
    true_top = {int(k): int(c) for k, c in zip(uniq[order[:10]],
                                               cnt[order[:10]])}

    rep = eng.heavy_hitters(10)
    print("rank  key          estimate      true      err    share")
    for i, (k, e, s) in enumerate(zip(rep.keys, rep.estimates, rep.shares)):
        truth = true_top.get(int(k))
        err = f"{(e - truth) / truth:+7.1%}" if truth else "  (not top-10)"
        print(f"{i:4d}  {int(k):>10d}  {e:>10.0f}  {truth or '-':>8}  {err}"
              f"  {s:6.2%}")
    hit = len(set(rep.keys.tolist()) & set(true_top)) / 10
    print(f"\ntop-10 recall: {hit:.0%}")


if __name__ == "__main__":
    main()
