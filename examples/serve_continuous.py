"""Continuous-batching example: N staggered requests through the block-paged
packed-F2P KV pool (DESIGN.md §12).

Serves a queue of mixed-length requests arriving at different times through
:class:`repro.serve.BatchedEngine` — dynamic admission into fixed decode
slots over a paged pool of packed-KV slabs — then replays every request
one-at-a-time through the sequential :class:`repro.serve.Engine` and asserts
the greedy outputs are BIT-FOR-BIT identical. Reports aggregate tokens/s for
both, plus the pool's packed-vs-logical-f32 footprint.

    PYTHONPATH=src python examples/serve_continuous.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import init_params
from repro.serve import (BatchedEngine, BatchedServeConfig, Engine, Request,
                         ServeConfig)


def main():
    cfg = smoke_config("llama3_2_3b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(42)
    n_req, slots, max_seq = 12, 4, 64
    reqs = [Request(uid=u + 1,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 25))
                                        ).astype(np.int32),
                    max_new=int(rng.integers(8, 25)),
                    arrival=3 * u)           # staggered arrivals
            for u in range(n_req)]

    eng = BatchedEngine(cfg, BatchedServeConfig(slots=slots,
                                                max_seq=max_seq), params)
    eng.run(reqs)                            # warmup: compile outside clock
    t0 = time.perf_counter()
    out = eng.run(reqs)
    dt_b = time.perf_counter() - t0
    ntok = sum(len(v) for v in out.values())

    seq = Engine(cfg, ServeConfig(batch=1, max_seq=max_seq,
                                  quantized_kv=True, packed_kv=True,
                                  fused_attention=True), params)
    for r in reqs:                           # warmup each prompt shape
        seq.generate(r.tokens[None], 2)
    t0 = time.perf_counter()
    want = {r.uid: np.asarray(seq.generate(r.tokens[None], r.max_new)[0],
                              np.int32) for r in reqs}
    dt_s = time.perf_counter() - t0

    for r in reqs:
        assert np.array_equal(out[r.uid], want[r.uid]), \
            f"request {r.uid}: batched output diverged from sequential"
    print(f"{n_req} requests bit-for-bit identical to the sequential engine")

    pool = eng.stats["pool"]
    print(f"batched   : {ntok / dt_b:8.0f} tok/s "
          f"({slots} slots, occupancy {eng.stats['slot_occupancy']:.2f}, "
          f"{eng.stats.get('preemptions', 0)} preemptions)")
    print(f"sequential: {ntok / dt_s:8.0f} tok/s (batch=1 replay)")
    print(f"speedup   : {dt_s / dt_b:8.2f}x")
    print(f"KV pool   : {pool['pool_bytes_packed'] / 1e3:.1f} KB packed vs "
          f"{pool['pool_bytes_logical_f32'] / 1e3:.1f} KB logical f32 "
          f"({pool['peak_used']}/{pool['n_pages']} pages peak)")


if __name__ == "__main__":
    main()
