"""Continuous-batching example: N staggered requests through the block-paged
packed-F2P KV pool (DESIGN.md §12), with optional observability capture.

Serves a queue of mixed-length requests arriving at different times through
:class:`repro.serve.BatchedEngine` — dynamic admission into fixed decode
slots whose KV is attended THROUGH page tables over the packed pool slabs
(DESIGN.md §14; no dense slot rows) — then replays every request through
the copy-in engine (``paged_decode=False``) and one-at-a-time through the
sequential :class:`repro.serve.Engine`, asserting the greedy outputs are
BIT-FOR-BIT identical three ways. Reports aggregate tokens/s, plus the
pool's packed-vs-logical-f32 footprint.

``--trace PATH`` arms the obs span tracer (DESIGN.md §13) for the timed run
and writes a Chrome/Perfetto trace_event JSON: open it at https://ui.perfetto.dev
to see the engine row (round/prefill spans, admit/preempt/evict/readmit/
retire markers, slot+pool counters) and one row per request with its
``ttft`` and ``decode`` spans. The script then validates the trace — JSON
loads, every request has its per-request spans, and the metrics registry
agrees with the engine's stats view — and exits nonzero on any mismatch
(the CI examples-smoke contract).

    PYTHONPATH=src python examples/serve_continuous.py [--trace out.trace.json]
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro import obs
from repro.configs import smoke_config
from repro.models import init_params
from repro.serve import (BatchedEngine, BatchedServeConfig, Engine, Request,
                         ServeConfig)


def _validate_trace(path: str, reqs, eng) -> None:
    """The examples-smoke acceptance: the written trace must be loadable
    Chrome trace_event JSON with per-request ttft/decode spans for EVERY
    request, and the obs metrics must agree with the engine stats view."""
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    assert isinstance(events, list) and events, "empty trace"
    for ev in events:
        assert {"name", "ph", "pid", "tid"} <= set(ev), f"malformed: {ev}"
        if ev["ph"] in ("X", "i", "C"):
            assert "ts" in ev, f"timed event without ts: {ev}"
        if ev["ph"] == "X":
            assert ev["dur"] >= 0, f"negative duration: {ev}"
    by_req = {}
    for ev in events:
        if ev["ph"] == "X" and ev["name"] in ("ttft", "decode"):
            by_req.setdefault(ev["args"]["uid"], set()).add(ev["name"])
    for r in reqs:
        assert by_req.get(r.uid) == {"ttft", "decode"}, \
            f"request {r.uid}: missing per-request spans ({by_req.get(r.uid)})"
    names = {ev["name"] for ev in events}
    for want in ("round", "prefill", "admit", "retire"):
        assert want in names, f"engine timeline missing {want!r} events"
    # metrics <-> stats consistency: the registry's exact shadows ARE the
    # engine.stats numbers, and the TTFT histogram saw every request
    # (export from the engine's own registry — the weak obs name registry
    # is latest-wins, and the copy-in reference engine also registered)
    snap = eng.metrics.export()
    assert snap["counters"]["prefills"]["exact"] == eng.stats["prefills"]
    assert snap["histograms"]["ttft_ms"]["count"] == eng.stats["prefills"]
    assert snap["counters"]["emitted_tokens"]["exact"] == \
        eng.stats["emitted_tokens"]
    print(f"trace OK  : {len(events)} events, {len(by_req)} request rows "
          f"-> {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="",
                    help="write a Chrome/Perfetto trace_event JSON here "
                         "(arms obs tracing for the timed run)")
    args = ap.parse_args()

    cfg = smoke_config("llama3_2_3b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(42)
    n_req, slots, max_seq = 12, 4, 64
    reqs = [Request(uid=u + 1,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 25))
                                        ).astype(np.int32),
                    max_new=int(rng.integers(8, 25)),
                    arrival=3 * u)           # staggered arrivals
            for u in range(n_req)]

    eng = BatchedEngine(cfg, BatchedServeConfig(slots=slots,
                                                max_seq=max_seq), params)
    eng.run(reqs)                            # warmup: compile outside clock
    if args.trace:
        obs.enable(trace=True)
    t0 = time.perf_counter()
    out = eng.run(reqs)
    dt_b = time.perf_counter() - t0
    if args.trace:
        obs.get().tracer.write_chrome(args.trace)
        obs.disable()
    ntok = sum(len(v) for v in out.values())

    # the PR-8 copy-in engine (dense slot rows, pages gathered in) is the
    # paged path's bitwise reference — same queue, same schedule
    ceng = BatchedEngine(cfg, BatchedServeConfig(slots=slots, max_seq=max_seq,
                                                 paged_decode=False), params)
    cout = ceng.run(reqs)
    for r in reqs:
        assert np.array_equal(out[r.uid], cout[r.uid]), \
            f"request {r.uid}: paged output diverged from copy-in"

    seq = Engine(cfg, ServeConfig(batch=1, max_seq=max_seq,
                                  quantized_kv=True, packed_kv=True,
                                  fused_attention=True), params)
    for r in reqs:                           # warmup each prompt shape
        seq.generate(r.tokens[None], 2)
    t0 = time.perf_counter()
    want = {r.uid: np.asarray(seq.generate(r.tokens[None], r.max_new)[0],
                              np.int32) for r in reqs}
    dt_s = time.perf_counter() - t0

    for r in reqs:
        assert np.array_equal(out[r.uid], want[r.uid]), \
            f"request {r.uid}: batched output diverged from sequential"
    print(f"{n_req} requests bit-for-bit identical to the copy-in engine "
          f"AND the sequential engine")

    pool = eng.stats["pool"]
    print(f"batched   : {ntok / dt_b:8.0f} tok/s "
          f"(paged decode, {slots} slots, occupancy "
          f"{eng.stats['slot_occupancy']:.2f}, "
          f"{eng.stats.get('preemptions', 0)} preemptions)")
    print(f"sequential: {ntok / dt_s:8.0f} tok/s (batch=1 replay)")
    print(f"speedup   : {dt_s / dt_b:8.2f}x")
    print(f"KV pool   : {pool['pool_bytes_packed'] / 1e3:.1f} KB packed vs "
          f"{pool['pool_bytes_logical_f32'] / 1e3:.1f} KB logical f32 "
          f"({pool['peak_used']}/{pool['n_pages']} pages peak)")
    snap = eng.metrics.export()
    print(f"latency   : ttft p50 {snap['histograms']['ttft_ms']['p50']:.1f} ms"
          f", tbt p50 {snap['histograms']['tbt_ms']['p50']:.2f} ms "
          f"(F2P-estimated histograms)")

    if args.trace:
        _validate_trace(args.trace, reqs, eng)


if __name__ == "__main__":
    main()
