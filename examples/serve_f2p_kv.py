"""Serving example: batched generation with an F2P8-quantized KV cache.

Loads (or trains briefly) a small LM, then serves a batch of prompts twice —
exact bf16 cache vs F2P8 cache — and reports memory saved + output agreement.

    PYTHONPATH=src python examples/serve_f2p_kv.py

The cache format here is the hardcoded default (attention.KV_FMT); to pick
formats per layer from calibrated K/V statistics, pass a
repro.autotune FormatPolicy via ``ServeConfig(kv_policy=...)`` (rule paths
``kv/b<i>`` — see DESIGN.md §8.4 and examples/autotune_study.py).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.models import init_caches, init_params
from repro.models.config import ModelConfig, dense_pattern
from repro.serve import Engine, ServeConfig


def main():
    cfg = ModelConfig(name="serve-demo", n_layers=4, d_model=256, n_heads=8,
                      n_kv_heads=4, d_ff=512, vocab_size=1024,
                      pattern=dense_pattern(), dtype="float32", remat=False)
    params = init_params(cfg, jax.random.PRNGKey(7))
    B, S, new = 4, 32, 16
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size))

    outs = {}
    for quant in (False, True):
        scfg = ServeConfig(batch=B, max_seq=S + new, quantized_kv=quant)
        eng = Engine(cfg, scfg, params)
        outs[quant] = eng.generate(prompts, max_new=new)
        cache = init_caches(cfg, B, S + new, quantized_kv=quant)
        nbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
        print(f"quantized_kv={quant}: cache={nbytes/1e6:.2f} MB, "
              f"first row: {outs[quant][0][:8].tolist()}")

    agree = (outs[True] == outs[False]).mean()
    print(f"token agreement exact-vs-F2P8: {agree:.2%}")


if __name__ == "__main__":
    main()
