"""Quickstart: end-to-end training driver.

Trains a ~100M-parameter decoder-only LM for a few hundred steps on the
deterministic synthetic pipeline, with every framework feature on:
  * F2P8 error-feedback gradient compression (paper-powered),
  * fault-tolerant checkpointing (atomic, K-last, F2P16-compressed),
  * auto-resume: re-running the script continues from the last checkpoint,
  * F2P-LI telemetry counters for pipeline flow stats.

    PYTHONPATH=src python examples/quickstart.py --steps 300

On this CPU container a ~100M model step is slow; --small trains a ~10M
variant (same code path) in a couple of minutes.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.data import DataConfig, host_batch
from repro.models.config import ModelConfig, dense_pattern
from repro.optim import AdamWConfig, CompressionConfig
from repro.telemetry import FlowStats
from repro.train import checkpoint, init_train_state, make_train_step


def model_100m():
    return ModelConfig(name="quickstart-100m", n_layers=12, d_model=768,
                       n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32768,
                       pattern=dense_pattern(), dtype="float32", remat=False,
                       rope_theta=10_000.0)


def model_small():
    return ModelConfig(name="quickstart-10m", n_layers=4, d_model=256,
                       n_heads=8, n_kv_heads=4, d_ff=1024, vocab_size=4096,
                       pattern=dense_pattern(), dtype="float32", remat=False,
                       rope_theta=10_000.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-compress", action="store_true")
    args = ap.parse_args()

    cfg = model_small() if args.small else model_100m()
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")
    ocfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    ccfg = CompressionConfig(enabled=not args.no_compress)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    flows = FlowStats(["tokens_in", "steps", "checkpoints"])

    os.makedirs(args.ckpt_dir, exist_ok=True)
    start = checkpoint.latest_step(args.ckpt_dir)
    state = init_train_state(cfg, ocfg, ccfg, jax.random.PRNGKey(0))
    if start is not None:
        state, start = checkpoint.restore(args.ckpt_dir, state)
        print(f"resumed from step {start}")
    else:
        start = 0

    step_fn = jax.jit(make_train_step(cfg, ocfg, ccfg), donate_argnums=0)
    t0 = time.time()
    for step in range(start, args.steps):
        batch = host_batch(dcfg, step)
        state, m = step_fn(state, {k: jnp.asarray(v) for k, v in batch.items()})
        flows.add("tokens_in", args.batch * args.seq)
        flows.add("steps")
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"lr {float(m['lr']):.2e} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if step > 0 and step % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, step, state, compress=True)
            flows.add("checkpoints")
    checkpoint.save(args.ckpt_dir, args.steps, state, compress=True)
    print("telemetry (F2P-LI counters):", flows.snapshot())
    print("done.")


if __name__ == "__main__":
    main()
